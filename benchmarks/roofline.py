"""Aggregate experiments/dryrun/*.json into the roofline table
(EXPERIMENTS.md §Roofline) and a machine-readable summary.

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str):
    cells = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c) -> str:
    r = c["roofline"]
    mem = c.get("memory_analysis", {})
    peak = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
    uf = r.get("useful_fraction")
    return ("| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {x:.4f} | "
            "{dom} | {uf} | {peak:.1f} |").format(
        arch=c["arch"], shape=c["shape"], mesh=c["mesh"],
        c=r["compute_s"], m=r["memory_s"], x=r["collective_s"],
        dom=r["dominant"].replace("_s", ""),
        uf=f"{uf:.2f}" if uf else "-",
        peak=peak / 2 ** 30)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default=None, help="write markdown table here")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful_frac | peak_GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep] + [fmt_row(c) for c in cells]
    out = "\n".join(lines)
    print(out)
    # quick aggregates
    doms = {}
    for c in cells:
        doms[c["roofline"]["dominant"]] = doms.get(c["roofline"]["dominant"], 0) + 1
    print(f"\n# {len(cells)} cells; dominant-term counts: {doms}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(out + "\n")


if __name__ == "__main__":
    main()
