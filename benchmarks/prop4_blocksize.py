"""Paper Prop 4 (Appendix B): block-size sweep — per-iteration cost
k*(N/B + B) is minimized near B=sqrt(N); measured iterations included."""
import jax, jax.numpy as jnp
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, toy_denoiser


def main():
    model_fn = toy_denoiser()
    x0 = jax.random.normal(jax.random.PRNGKey(5), (1, 16))
    n = 256
    sched = make_schedule("ddpm_linear", n)
    for b in (4, 8, 16, 32, 64):
        r = run_pair(model_fn, sched, SolverConfig("ddim"), x0,
                     SRDSConfig(tol=1e-3, num_blocks=b))
        emit(f"prop4/B{b}", r["t_srds"] * 1e6,
             f"iters={r['iters']};eff_serial={r['eff_serial']};"
             f"per_iter={n//b + b};err={r['err']:.1e}")


if __name__ == "__main__":
    main()
