"""Shared benchmark utilities: toy/DiT denoisers, timing, CSV output.

All benches run on the single CPU device with small denoisers — the metrics
that transfer to TPU scale are the *paper's own hardware-independent units*
(SRDS iterations, effective serial evals, total evals) plus CPU wall-clock
ratios measured on identical hardware (the paper's Tables 2-4 structure).
"""
from __future__ import annotations

import dataclasses as dc
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import (DiffusionSchedule, SolverConfig, SRDSConfig,
                        make_schedule, resolve_blocks, sample_sequential,
                        srds_sample, srds_stats)
from repro.models.dit import dit_forward, init_dit

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def toy_denoiser(dim: int = 16, seed: int = 0):
    """Smooth nonlinear eps model — fast enough for N=1024 trajectories."""
    w1 = jax.random.normal(jax.random.PRNGKey(seed), (dim, dim)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim, dim)) * 0.4

    def model_fn(x, t):
        h = jnp.tanh(x @ w1) * (0.4 + 3e-4 * t)
        return jnp.tanh(h @ w2 + x * 0.1)

    return model_fn


def small_dit(name: str = "srds-dit-cifar", layers: int = 2, d: int = 64,
              img: int = 16, seed: int = 0):
    """A tiny-but-real DiT denoiser (attention+adaLN) for image benches."""
    cfg = dc.replace(get_arch(name), num_layers=layers, d_model=d,
                     num_heads=4, num_kv_heads=4, head_dim=d // 4, d_ff=4 * d,
                     patch_size=4, dtype="float32")
    params = init_dit(cfg, jax.random.PRNGKey(seed))

    def model_fn(x, t):
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return dit_forward(cfg, params, x, tb, use_kernel=False)

    return model_fn, cfg, img


def timeit(fn: Callable, *args, repeats: int = 3) -> float:
    """Median wall-clock seconds of a jitted call (post-warmup)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def run_pair(model_fn, sched, solver, x0, srds_cfg):
    """Returns dict with sequential + SRDS results and timings."""
    seq = jax.jit(lambda x: sample_sequential(model_fn, sched, solver, x))
    srd = jax.jit(lambda x: srds_sample(model_fn, sched, solver, x, srds_cfg))
    t_seq = timeit(seq, x0)
    t_srds = timeit(srd, x0)
    res = srd(x0)
    ref = seq(x0)
    err = float(jnp.mean(jnp.abs(res.sample - ref)))
    iters = int(res.iterations)
    st = srds_stats(sched, solver, srds_cfg, iters)
    stp = srds_stats(sched, solver, srds_cfg, iters, pipelined=True)
    seq_evals = sched.num_steps * solver.evals_per_step
    return dict(t_seq=t_seq, t_srds=t_srds, err=err, iters=iters,
                eff_serial=st.serial_evals, total=st.total_evals,
                eff_serial_pipelined=stp.serial_evals,
                seq_evals=seq_evals,
                # the paper's latency metric: parallel-device speedup is
                # bounded by seq_evals / eff_serial (CPU wall-clock on ONE
                # core cannot show it; see EXPERIMENTS.md)
                proj_speedup=seq_evals / max(st.serial_evals, 1),
                proj_speedup_pipelined=seq_evals / max(stp.serial_evals, 1))
